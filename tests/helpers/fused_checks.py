"""Retracing + donation regression checks for the fused routing engine
(4 emulated devices; subprocess-isolated like the other multi-device helpers).

The contract under test: N consecutive PulseService quanta and repeated
PulseEngine.execute calls with same-shaped pools compile exactly once (the
compiled-executable cache absorbs everything after the first), the resident
arena is uploaded once, and the donated pool buffer is consumed by the
executable (not silently copied)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as Spec  # noqa: E402

from repro.core import routing  # noqa: E402
from repro.core.engine import PulseEngine  # noqa: E402
from repro.core.structures import btree, linked_list  # noqa: E402
from repro.serving.admission import TraversalRequest  # noqa: E402
from repro.serving.traversal_service import PulseService, StructureSpec  # noqa: E402

RNG = np.random.default_rng(17)
P = 4


def _list_setup(n=64, B=16):
    keys = np.arange(n, dtype=np.int32)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P, policy="interleaved")
    it = linked_list.find_iterator()
    q = keys[RNG.integers(0, n, B)].astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    return it, ar, ptr0, scr0


def check_repeated_execute_compiles_once():
    """Same-shaped fused executions after the first must be pure cache hits:
    zero traces, zero executable-cache misses."""
    it, ar, ptr0, scr0 = _list_setup()
    mesh = jax.make_mesh((P,), ("mem",))
    eng = PulseEngine(ar, mesh=mesh)
    routing.reset_executable_caches()
    first = eng.execute(it, ptr0, scr0, max_iters=4096)
    assert routing.CACHE_STATS.traces >= 1  # the one compile
    assert routing.CACHE_STATS.misses == 1
    routing.CACHE_STATS.reset()
    for _ in range(4):
        res = eng.execute(it, ptr0, scr0, max_iters=4096)
        np.testing.assert_array_equal(res.scratch, first.scratch)
    assert routing.CACHE_STATS.traces == 0, routing.CACHE_STATS
    assert routing.CACHE_STATS.misses == 0, routing.CACHE_STATS
    assert routing.CACHE_STATS.hits == 4, routing.CACHE_STATS
    print(f"repeated execute ok: {routing.CACHE_STATS}")


def check_service_quanta_compile_once():
    """N consecutive PulseService quanta with fixed slot shapes: one compile
    per (structure, shape), then zero retraces for the rest of the run."""
    n = 96
    lkeys = np.arange(n, dtype=np.int32)
    lvals = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(lkeys, lvals, num_shards=P, policy="interleaved")
    mesh = jax.make_mesh((P,), ("mem",))
    eng = PulseEngine(ar, mesh=mesh)
    svc = PulseService(
        eng,
        {"list": StructureSpec(linked_list.find_iterator(), (head,))},
        slots_per_structure=8,
        quantum=4,
    )
    # warm: first quantum compiles the (iterator, pool-shape) executable
    svc.run([TraversalRequest(0, "list", int(lkeys[1]))])
    svc.metrics = type(svc.metrics)()  # drop warmup accounting
    routing.CACHE_STATS.reset()
    reqs = [
        TraversalRequest(1 + i, "list", int(lkeys[RNG.integers(0, n)]))
        for i in range(24)
    ]
    m = svc.run(reqs)
    assert m.completed == 24
    assert m.rounds >= 3  # several quanta actually ran
    assert routing.CACHE_STATS.traces == 0, routing.CACHE_STATS
    assert routing.CACHE_STATS.misses == 0, routing.CACHE_STATS
    assert routing.CACHE_STATS.hits >= m.engine_calls, (
        routing.CACHE_STATS,
        m.engine_calls,
    )
    print(
        f"service quanta ok: rounds={m.rounds} engine_calls={m.engine_calls} "
        f"{routing.CACHE_STATS}"
    )


def check_resident_arena_uploaded_once():
    """Consecutive executions reuse the device-resident arena buffers."""
    it, ar, ptr0, scr0 = _list_setup()
    mesh = jax.make_mesh((P,), ("mem",))
    routing.reset_executable_caches()
    routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, compact=True, fused=True
    )
    resident = routing._RESIDENT[(id(ar), mesh, "mem")]
    routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, compact=True, fused=True
    )
    assert routing._RESIDENT[(id(ar), mesh, "mem")] is resident
    assert all(not buf.is_deleted() for buf in resident)  # never donated
    print("resident arena ok: one upload, buffers alive")


def check_donated_pool_consumed():
    """The fused executable must consume (donate) the pool buffer it is
    handed -- and must not touch it afterwards (whitebox: call the cached
    executable directly with a pool we control)."""
    it, ar, ptr0, scr0 = _list_setup(B=16)
    mesh = jax.make_mesh((P,), ("mem",))
    routing.reset_executable_caches()
    routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, compact=True, fused=True
    )
    assert len(routing._FUSED_CACHE) == 1
    runner = next(iter(routing._FUSED_CACHE.values()))
    data, bounds, perms = routing._resident_arena(ar, mesh, "mem")
    L = 16  # Bp per shard, as built by distributed_execute for B=16
    pool = jax.device_put(
        routing.empty_records(P * L, it.scratch_words),
        NamedSharding(mesh, Spec("mem")),
    )
    out = runner(pool, data, bounds, perms, jnp.int32(4096), jnp.int32(1 << 16))
    jax.block_until_ready(out[0])
    assert pool.is_deleted(), "pool buffer was not donated to the executable"
    assert not data.is_deleted(), "resident arena must not be donated"
    print("donation ok: pool consumed, arena resident")


def check_pipelined_compiles_once_and_donates():
    """The wavefront-pipelined executable obeys the same cache + donation
    contract as the fused one: repeated same-shaped runs are pure cache hits
    (zero retraces), the handed-in pool buffer is consumed, and the resident
    arena survives.  Fused and pipelined runners coexist in the cache under
    distinct schedule keys."""
    it, ar, ptr0, scr0 = _list_setup()
    mesh = jax.make_mesh((P,), ("mem",))
    routing.reset_executable_caches()
    first, _ = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, compact=True,
        schedule="pipelined",
    )
    assert routing.CACHE_STATS.misses == 1
    routing.CACHE_STATS.reset()
    for _ in range(3):
        rec, _ = routing.distributed_execute(
            it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, compact=True,
            schedule="pipelined",
        )
        np.testing.assert_array_equal(rec, first)
    assert routing.CACHE_STATS.traces == 0, routing.CACHE_STATS
    assert routing.CACHE_STATS.misses == 0, routing.CACHE_STATS
    assert routing.CACHE_STATS.hits == 3, routing.CACHE_STATS
    # a fused run afterwards compiles its own executable (distinct key),
    # leaving the pipelined one cached
    routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, compact=True,
        schedule="fused",
    )
    assert len(routing._FUSED_CACHE) == 2, list(routing._FUSED_CACHE)

    # donation: call the cached pipelined runner directly with our own pool
    key = next(k for k in routing._FUSED_CACHE if "pipelined" in k)
    runner = routing._FUSED_CACHE[key]
    data, bounds, perms = routing._resident_arena(ar, mesh, "mem")
    L = 16
    pool = jax.device_put(
        routing.empty_records(P * L, it.scratch_words),
        NamedSharding(mesh, Spec("mem")),
    )
    out = runner(pool, data, bounds, perms, jnp.int32(4096), jnp.int32(1 << 16))
    jax.block_until_ready(out[0])
    assert pool.is_deleted(), "pipelined runner must donate the pool buffer"
    assert not data.is_deleted(), "resident arena must not be donated"
    print("pipelined cache+donation ok")


def check_pipelined_service_quanta_compile_once():
    """PulseService quanta on the pipelined schedule (the auto default for
    a meshed engine): one compile, then zero retraces across rounds."""
    n = 96
    lkeys = np.arange(n, dtype=np.int32)
    lvals = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(lkeys, lvals, num_shards=P, policy="interleaved")
    mesh = jax.make_mesh((P,), ("mem",))
    eng = PulseEngine(ar, mesh=mesh)
    svc = PulseService(
        eng,
        {"list": StructureSpec(linked_list.find_iterator(), (head,))},
        slots_per_structure=8,
        quantum=4,
        schedule="pipelined",
    )
    svc.run([TraversalRequest(0, "list", int(lkeys[1]))])
    svc.metrics = type(svc.metrics)()
    routing.CACHE_STATS.reset()
    reqs = [
        TraversalRequest(1 + i, "list", int(lkeys[RNG.integers(0, n)]))
        for i in range(24)
    ]
    m = svc.run(reqs)
    assert m.completed == 24
    assert routing.CACHE_STATS.traces == 0, routing.CACHE_STATS
    assert routing.CACHE_STATS.misses == 0, routing.CACHE_STATS
    print(
        f"pipelined service quanta ok: rounds={m.rounds} "
        f"engine_calls={m.engine_calls} {routing.CACHE_STATS}"
    )


if __name__ == "__main__":
    assert jax.device_count() == P, jax.devices()
    check_repeated_execute_compiles_once()
    check_service_quanta_compile_once()
    check_resident_arena_uploaded_once()
    check_donated_pool_consumed()
    check_pipelined_compiles_once_and_donates()
    check_pipelined_service_quanta_compile_once()
    print("ALL FUSED CHECKS PASSED")
