"""Fault-injection checks on the 8-shard mesh (the acceptance configuration):
every schedule x fabric must (a) die cleanly on an injected shard kill --
ShardFailure raised, input arena untouched, a clean rerun still matches the
oracle -- and (b) under fabric loss, park-and-retransmit until the final
records are bit-identical to the loss-free run."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import commit, routing  # noqa: E402
from repro.core.arena import ArenaBuilder  # noqa: E402
from repro.core.faults import FaultInjector, FaultPlan, ShardFailure  # noqa: E402
from repro.core.iterator import STATUS_DONE  # noqa: E402
from repro.core.structures import linked_list  # noqa: E402

RNG = np.random.default_rng(23)
P = 8

SCHEDULES = (
    ("dispatched", "dense"),
    ("fused", "dense"),
    ("fused", "ring"),
    ("pipelined", "dense"),
    ("pipelined", "ring"),
)


def _build():
    n = 64
    b = ArenaBuilder(512, 4, num_shards=P, policy="interleaved")
    keys = np.arange(10, 10 + n, dtype=np.int32)
    head = linked_list.build_into(b, keys, keys * 3)
    return b.finish(), head, keys


def check_kill_every_schedule():
    """A targeted shard kill raises ShardFailure on every schedule x fabric
    *without* publishing partial state, and a clean rerun of the same
    pre-state still matches the oracle bit for bit."""
    arena, head, _ = _build()
    data0 = np.asarray(arena.data).copy()
    heap0 = np.asarray(arena.heap).copy()
    it = linked_list.insert_iterator()
    newk = (np.arange(16, dtype=np.int32) + 900)
    p0, s0 = it.init(jnp.asarray(newk), jnp.asarray(newk * 2), head)
    rec_o, st_o, ar_o = commit.sequential_commit_execute(
        it, arena, p0, s0, max_iters=4096
    )
    mesh = jax.make_mesh((P,), ("mem",))
    for schedule, fabric in SCHEDULES:
        inj = FaultInjector(FaultPlan(kill_shard=2, kill_superstep=3))
        try:
            routing.distributed_execute(
                it, arena, p0, s0, mesh=mesh, max_iters=4096,
                compact=True, schedule=schedule, fabric=fabric,
                fault_injector=inj,
            )
            raise AssertionError(f"{schedule}/{fabric}: kill did not fire")
        except ShardFailure as e:
            assert (e.shard, e.superstep) == (2, 3), (schedule, fabric, e)
        tag = f"kill/{schedule}/{fabric}"
        # the input arena is untouched: nothing partial was published
        np.testing.assert_array_equal(np.asarray(arena.data), data0, err_msg=tag)
        np.testing.assert_array_equal(np.asarray(arena.heap), heap0, err_msg=tag)
        # the same pre-state replays cleanly to the oracle's answer
        rec_d, st_d, ar_d = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
        )
        np.testing.assert_array_equal(rec_d, rec_o, err_msg=tag)
        np.testing.assert_array_equal(
            np.asarray(ar_d.data), np.asarray(ar_o.data), err_msg=tag
        )
        np.testing.assert_array_equal(
            np.asarray(ar_d.heap), np.asarray(ar_o.heap), err_msg=tag
        )
        assert st_d.commits == st_o.commits
        print(f"{tag} ok (died before superstep 3, clean rerun matches oracle)")


def check_kill_superstep_counting():
    """kill_superstep is 1-based fire-before: killing at superstep 1 means
    zero supersteps ran; a kill past the run's natural length never fires."""
    arena, head, keys = _build()
    it = linked_list.find_iterator()
    p0, s0 = it.init(jnp.asarray(keys[:16]), head)
    mesh = jax.make_mesh((P,), ("mem",))
    rec_ref, st_ref = routing.distributed_execute(
        it, arena, p0, s0, mesh=mesh, max_iters=4096,
        compact=True, schedule="dispatched", fabric="dense",
    )
    for schedule in ("dispatched", "fused"):
        inj = FaultInjector(FaultPlan(kill_shard=0, kill_superstep=1))
        try:
            routing.distributed_execute(
                it, arena, p0, s0, mesh=mesh, max_iters=4096,
                compact=True, schedule=schedule, fabric="dense",
                fault_injector=inj,
            )
            raise AssertionError(f"{schedule}: superstep-1 kill did not fire")
        except ShardFailure as e:
            assert e.superstep == 1
        # a kill scheduled after completion is unreachable: run finishes
        inj = FaultInjector(
            FaultPlan(kill_shard=0, kill_superstep=st_ref.supersteps + 1)
        )
        rec, st = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric="dense",
            fault_injector=inj,
        )
        assert not inj.fired
        np.testing.assert_array_equal(rec, rec_ref, err_msg=schedule)
    print("kill superstep counting ok (1-based, fire-before semantics)")


def check_drop_retransmit_identity():
    """Fabric loss (park-and-retransmit) must not change any final record:
    dropped records retry until they cross, so only superstep counts grow."""
    arena, head, keys = _build()
    it = linked_list.find_iterator()
    q = keys[RNG.permutation(len(keys))[:32]]
    p0, s0 = it.init(jnp.asarray(q), head)
    mesh = jax.make_mesh((P,), ("mem",))
    for schedule, fabric in SCHEDULES:
        rec_ref, st_ref = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
        )
        inj = FaultInjector(FaultPlan(drop_prob=0.4, drop_seed=7))
        rec, st = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
            fault_injector=inj,
        )
        tag = f"drop/{schedule}/{fabric}"
        np.testing.assert_array_equal(rec, rec_ref, err_msg=tag)
        assert (rec[:, routing.F_STATUS] == STATUS_DONE).all(), tag
        assert st.supersteps >= st_ref.supersteps, (tag, st.supersteps)
        # replays are deterministic: same seed -> same superstep count
        inj2 = FaultInjector(FaultPlan(drop_prob=0.4, drop_seed=7))
        rec2, st2 = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
            fault_injector=inj2,
        )
        np.testing.assert_array_equal(rec2, rec, err_msg=tag)
        assert st2.supersteps == st.supersteps, tag
        print(
            f"{tag} ok: supersteps {st_ref.supersteps} -> {st.supersteps}, "
            f"records identical"
        )


def check_drop_write_path_validity():
    """Loss under the *write* path: delaying a record's crossing legally
    shifts which commit superstep it lands in, so the exact serialization
    (ALLOC addresses, CAS retry counts) may differ from the loss-free run --
    but the result must still be a *valid* one (every insert lands, every
    inserted key findable) and the seeded loss mask makes it exactly
    replayable."""
    from repro.core.iterator import execute_batched

    arena, head, _ = _build()
    it = linked_list.insert_iterator()
    newk = (np.arange(12, dtype=np.int32) + 700)
    p0, s0 = it.init(jnp.asarray(newk), jnp.asarray(newk + 1), head)
    mesh = jax.make_mesh((P,), ("mem",))
    for schedule, fabric in (("dispatched", "dense"), ("pipelined", "ring")):
        inj = FaultInjector(FaultPlan(drop_prob=0.3, drop_seed=3))
        rec, st, ar = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
            fault_injector=inj,
        )
        tag = f"drop-write/{schedule}/{fabric}"
        assert (rec[:, routing.F_STATUS] == STATUS_DONE).all(), tag
        assert st.commits > 0, tag
        fit = linked_list.find_iterator()
        fp, fs = fit.init(jnp.asarray(newk), head)
        _, fscr, _, _ = execute_batched(fit, ar, fp, fs, max_iters=4096)
        assert (np.asarray(fscr)[:, 2] == 1).all(), tag
        # seeded loss replays bit-identically (records AND final arena)
        inj2 = FaultInjector(FaultPlan(drop_prob=0.3, drop_seed=3))
        rec2, st2, ar2 = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
            fault_injector=inj2,
        )
        np.testing.assert_array_equal(rec2, rec, err_msg=tag)
        np.testing.assert_array_equal(
            np.asarray(ar2.data), np.asarray(ar.data), err_msg=tag
        )
        np.testing.assert_array_equal(
            np.asarray(ar2.heap), np.asarray(ar.heap), err_msg=tag
        )
        assert st2.commits == st.commits, tag
        print(
            f"{tag} ok: commits={st.commits}, all inserts landed, "
            f"replay bit-identical"
        )


def check_delay_identity():
    """A straggler shard (dispatched path) slows the run but changes no
    result -- delay is purely temporal."""
    import time

    arena, head, keys = _build()
    it = linked_list.find_iterator()
    p0, s0 = it.init(jnp.asarray(keys[:16]), head)
    mesh = jax.make_mesh((P,), ("mem",))
    rec_ref, st_ref = routing.distributed_execute(
        it, arena, p0, s0, mesh=mesh, max_iters=4096,
        compact=True, schedule="dispatched", fabric="dense",
    )
    inj = FaultInjector(FaultPlan(delay_shard=1, delay_s=0.02))
    t0 = time.perf_counter()
    rec, st = routing.distributed_execute(
        it, arena, p0, s0, mesh=mesh, max_iters=4096,
        compact=True, schedule="dispatched", fabric="dense",
        fault_injector=inj,
    )
    dt = time.perf_counter() - t0
    np.testing.assert_array_equal(rec, rec_ref)
    assert st.supersteps == st_ref.supersteps
    assert dt >= 0.02 * st.supersteps, (dt, st.supersteps)
    print(f"delay identity ok: {st.supersteps} supersteps, {dt * 1e3:.0f}ms")


if __name__ == "__main__":
    assert jax.device_count() == P, jax.devices()
    check_kill_every_schedule()
    check_kill_superstep_counting()
    check_drop_retransmit_identity()
    check_drop_write_path_validity()
    check_delay_identity()
    print("ALL FAULT-INJECTION CHECKS PASSED")
