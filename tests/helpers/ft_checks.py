"""Fault-injection checks on the 8-shard mesh (the acceptance configuration):
every schedule x fabric must (a) die cleanly on an injected shard kill --
ShardFailure raised, input arena untouched, a clean rerun still matches the
oracle -- and (b) under fabric loss, park-and-retransmit until the final
records are bit-identical to the loss-free run."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import commit, routing  # noqa: E402
from repro.core.arena import ArenaBuilder  # noqa: E402
from repro.core.faults import FaultInjector, FaultPlan, ShardFailure  # noqa: E402
from repro.core.iterator import STATUS_DONE  # noqa: E402
from repro.core.structures import linked_list  # noqa: E402

RNG = np.random.default_rng(23)
P = 8

SCHEDULES = (
    ("dispatched", "dense"),
    ("fused", "dense"),
    ("fused", "ring"),
    ("pipelined", "dense"),
    ("pipelined", "ring"),
)


def _build():
    n = 64
    b = ArenaBuilder(512, 4, num_shards=P, policy="interleaved")
    keys = np.arange(10, 10 + n, dtype=np.int32)
    head = linked_list.build_into(b, keys, keys * 3)
    return b.finish(), head, keys


def check_kill_every_schedule():
    """A targeted shard kill raises ShardFailure on every schedule x fabric
    *without* publishing partial state, and a clean rerun of the same
    pre-state still matches the oracle bit for bit."""
    arena, head, _ = _build()
    data0 = np.asarray(arena.data).copy()
    heap0 = np.asarray(arena.heap).copy()
    it = linked_list.insert_iterator()
    newk = (np.arange(16, dtype=np.int32) + 900)
    p0, s0 = it.init(jnp.asarray(newk), jnp.asarray(newk * 2), head)
    rec_o, st_o, ar_o = commit.sequential_commit_execute(
        it, arena, p0, s0, max_iters=4096
    )
    mesh = jax.make_mesh((P,), ("mem",))
    for schedule, fabric in SCHEDULES:
        inj = FaultInjector(FaultPlan(kill_shard=2, kill_superstep=3))
        try:
            routing.distributed_execute(
                it, arena, p0, s0, mesh=mesh, max_iters=4096,
                compact=True, schedule=schedule, fabric=fabric,
                fault_injector=inj,
            )
            raise AssertionError(f"{schedule}/{fabric}: kill did not fire")
        except ShardFailure as e:
            assert (e.shard, e.superstep) == (2, 3), (schedule, fabric, e)
        tag = f"kill/{schedule}/{fabric}"
        # the input arena is untouched: nothing partial was published
        np.testing.assert_array_equal(np.asarray(arena.data), data0, err_msg=tag)
        np.testing.assert_array_equal(np.asarray(arena.heap), heap0, err_msg=tag)
        # the same pre-state replays cleanly to the oracle's answer
        rec_d, st_d, ar_d = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
        )
        np.testing.assert_array_equal(rec_d, rec_o, err_msg=tag)
        np.testing.assert_array_equal(
            np.asarray(ar_d.data), np.asarray(ar_o.data), err_msg=tag
        )
        np.testing.assert_array_equal(
            np.asarray(ar_d.heap), np.asarray(ar_o.heap), err_msg=tag
        )
        assert st_d.commits == st_o.commits
        print(f"{tag} ok (died before superstep 3, clean rerun matches oracle)")


def check_kill_superstep_counting():
    """kill_superstep is 1-based fire-before: killing at superstep 1 means
    zero supersteps ran; a kill past the run's natural length never fires."""
    arena, head, keys = _build()
    it = linked_list.find_iterator()
    p0, s0 = it.init(jnp.asarray(keys[:16]), head)
    mesh = jax.make_mesh((P,), ("mem",))
    rec_ref, st_ref = routing.distributed_execute(
        it, arena, p0, s0, mesh=mesh, max_iters=4096,
        compact=True, schedule="dispatched", fabric="dense",
    )
    for schedule in ("dispatched", "fused"):
        inj = FaultInjector(FaultPlan(kill_shard=0, kill_superstep=1))
        try:
            routing.distributed_execute(
                it, arena, p0, s0, mesh=mesh, max_iters=4096,
                compact=True, schedule=schedule, fabric="dense",
                fault_injector=inj,
            )
            raise AssertionError(f"{schedule}: superstep-1 kill did not fire")
        except ShardFailure as e:
            assert e.superstep == 1
        # a kill scheduled after completion is unreachable: run finishes
        inj = FaultInjector(
            FaultPlan(kill_shard=0, kill_superstep=st_ref.supersteps + 1)
        )
        rec, st = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric="dense",
            fault_injector=inj,
        )
        assert not inj.fired
        np.testing.assert_array_equal(rec, rec_ref, err_msg=schedule)
    print("kill superstep counting ok (1-based, fire-before semantics)")


def check_drop_retransmit_identity():
    """Fabric loss (park-and-retransmit) must not change any final record:
    dropped records retry until they cross, so only superstep counts grow."""
    arena, head, keys = _build()
    it = linked_list.find_iterator()
    q = keys[RNG.permutation(len(keys))[:32]]
    p0, s0 = it.init(jnp.asarray(q), head)
    mesh = jax.make_mesh((P,), ("mem",))
    for schedule, fabric in SCHEDULES:
        rec_ref, st_ref = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
        )
        inj = FaultInjector(FaultPlan(drop_prob=0.4, drop_seed=7))
        rec, st = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
            fault_injector=inj,
        )
        tag = f"drop/{schedule}/{fabric}"
        np.testing.assert_array_equal(rec, rec_ref, err_msg=tag)
        assert (rec[:, routing.F_STATUS] == STATUS_DONE).all(), tag
        assert st.supersteps >= st_ref.supersteps, (tag, st.supersteps)
        # replays are deterministic: same seed -> same superstep count
        inj2 = FaultInjector(FaultPlan(drop_prob=0.4, drop_seed=7))
        rec2, st2 = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
            fault_injector=inj2,
        )
        np.testing.assert_array_equal(rec2, rec, err_msg=tag)
        assert st2.supersteps == st.supersteps, tag
        print(
            f"{tag} ok: supersteps {st_ref.supersteps} -> {st.supersteps}, "
            f"records identical"
        )


def check_drop_write_path_validity():
    """Loss under the *write* path: delaying a record's crossing legally
    shifts which commit superstep it lands in, so the exact serialization
    (ALLOC addresses, CAS retry counts) may differ from the loss-free run --
    but the result must still be a *valid* one (every insert lands, every
    inserted key findable) and the seeded loss mask makes it exactly
    replayable."""
    from repro.core.iterator import execute_batched

    arena, head, _ = _build()
    it = linked_list.insert_iterator()
    newk = (np.arange(12, dtype=np.int32) + 700)
    p0, s0 = it.init(jnp.asarray(newk), jnp.asarray(newk + 1), head)
    mesh = jax.make_mesh((P,), ("mem",))
    for schedule, fabric in (("dispatched", "dense"), ("pipelined", "ring")):
        inj = FaultInjector(FaultPlan(drop_prob=0.3, drop_seed=3))
        rec, st, ar = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
            fault_injector=inj,
        )
        tag = f"drop-write/{schedule}/{fabric}"
        assert (rec[:, routing.F_STATUS] == STATUS_DONE).all(), tag
        assert st.commits > 0, tag
        fit = linked_list.find_iterator()
        fp, fs = fit.init(jnp.asarray(newk), head)
        _, fscr, _, _ = execute_batched(fit, ar, fp, fs, max_iters=4096)
        assert (np.asarray(fscr)[:, 2] == 1).all(), tag
        # seeded loss replays bit-identically (records AND final arena)
        inj2 = FaultInjector(FaultPlan(drop_prob=0.3, drop_seed=3))
        rec2, st2, ar2 = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule=schedule, fabric=fabric,
            fault_injector=inj2,
        )
        np.testing.assert_array_equal(rec2, rec, err_msg=tag)
        np.testing.assert_array_equal(
            np.asarray(ar2.data), np.asarray(ar.data), err_msg=tag
        )
        np.testing.assert_array_equal(
            np.asarray(ar2.heap), np.asarray(ar.heap), err_msg=tag
        )
        assert st2.commits == st.commits, tag
        print(
            f"{tag} ok: commits={st.commits}, all inserts landed, "
            f"replay bit-identical"
        )


def check_delay_identity():
    """A straggler shard (dispatched path) slows the run but changes no
    result -- delay is purely temporal.  The delay is *attributable*: the
    straggler sleeps only before supersteps in which it actually serves
    work, so the slowdown is at least one delay period but (unlike the old
    every-superstep model) not necessarily supersteps * delay."""
    import time

    arena, head, keys = _build()
    it = linked_list.find_iterator()
    p0, s0 = it.init(jnp.asarray(keys[:16]), head)
    mesh = jax.make_mesh((P,), ("mem",))
    rec_ref, st_ref = routing.distributed_execute(
        it, arena, p0, s0, mesh=mesh, max_iters=4096,
        compact=True, schedule="dispatched", fabric="dense",
    )
    inj = FaultInjector(FaultPlan(delay_shard=1, delay_s=0.02))
    t0 = time.perf_counter()
    rec, st = routing.distributed_execute(
        it, arena, p0, s0, mesh=mesh, max_iters=4096,
        compact=True, schedule="dispatched", fabric="dense",
        fault_injector=inj,
    )
    dt = time.perf_counter() - t0
    np.testing.assert_array_equal(rec, rec_ref)
    assert st.supersteps == st_ref.supersteps
    # shard 1 serves work in at least one superstep of a 16-key find
    assert dt >= 0.02, (dt, st.supersteps)
    print(f"delay identity ok: {st.supersteps} supersteps, {dt * 1e3:.0f}ms")


def check_replica_fanout_matrix():
    """Routing-level replica fan-out, every dead-primary case: with R=2
    replication, reads keep completing when any single primary is dead, and
    the payload fields (status, iters, scratch, ptr) match the failure-free
    run exactly -- only hops/supersteps may shift (records are served
    elsewhere, their state trajectory never changes).  The replicated
    sequential-commit oracle must match the device run bit-for-bit."""
    arena, head, keys = _build()
    it = linked_list.find_iterator()
    q = keys[RNG.permutation(len(keys))[:32]]
    p0, s0 = it.init(jnp.asarray(q), head)
    mesh = jax.make_mesh((P,), ("mem",))
    plan = routing.make_replica_plan(P, policy="failover")
    data = np.asarray(arena.data)
    bounds = np.asarray(arena.bounds)
    rep_rows = np.zeros_like(data)
    for holder, p in enumerate(plan.primary_map):
        if p >= 0:
            rep_rows[bounds[holder]:bounds[holder + 1]] = (
                data[bounds[p]:bounds[p + 1]]
            )
    rec_ref, _ = routing.distributed_execute(
        it, arena, p0, s0, mesh=mesh, max_iters=4096,
        compact=True, schedule="dispatched", fabric="dense",
    )
    payload = [routing.F_ID, routing.F_PTR, routing.F_STATUS, routing.F_ITERS]
    for dead in range(P):
        mask = np.zeros(P, bool)
        mask[dead] = True
        ctx = routing.ReplicaContext(plan=plan, rep_rows=rep_rows, dead_mask=mask)
        rec, st = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=4096,
            compact=True, schedule="dispatched", fabric="dense",
            replication=ctx,
        )
        tag = f"fanout/dead={dead}"
        rec_np = np.asarray(rec)
        ref_np = np.asarray(rec_ref)
        np.testing.assert_array_equal(
            rec_np[:, payload], ref_np[:, payload], err_msg=tag
        )
        np.testing.assert_array_equal(
            rec_np[:, routing.F_SCRATCH:], ref_np[:, routing.F_SCRATCH:],
            err_msg=tag,
        )
        assert (rec_np[:, routing.F_STATUS] == STATUS_DONE).all(), tag
        # replicated oracle: bit-identical including hops + supersteps
        rec_o, st_o = commit.sequential_commit_execute(
            it, arena, p0, s0, max_iters=4096, k_local=4, compact=True,
            replication=ctx,
        )
        np.testing.assert_array_equal(rec_np, np.asarray(rec_o), err_msg=tag)
        assert st.supersteps == st_o.supersteps, (tag, st.supersteps)
    print(f"replica fan-out matrix ok: {P} dead-primary cases, payload "
          f"identical, oracle bit-identical")


def check_replication_service_matrix():
    """Service-level 8-shard kill matrix: for every shard, kill it mid-
    stream under the full serving stack with R=2 replication on and assert
    (a) the hot standby is bit-identical to the primary after every write
    quantum (verify_every_quantum raises on any divergence), (b) read-only
    tenants complete with zero STATUS_RETRY and zero retries charged while
    the primary is dead, and (c) post-recovery primary == replica ==
    durable oracle (snapshot + commit-log replay)."""
    import tempfile

    from repro.core.engine import PulseEngine  # noqa: E402
    from repro.distributed.arena_ft import (  # noqa: E402
        ArenaStore,
        FaultToleranceConfig,
        ReplicationConfig,
    )
    from repro.serving.admission import TraversalRequest  # noqa: E402
    from repro.serving.traversal_service import (  # noqa: E402
        PulseService,
        StructureSpec,
    )

    keys = np.arange(100, 164, dtype=np.int32)

    def serve(tmp, plan, *, reads_only=False, dead_rounds=6):
        b = ArenaBuilder(512, 4, num_shards=P, policy="interleaved")
        head = linked_list.build_into(b, keys, keys * 2)
        inj = FaultInjector(plan) if plan is not None else None
        eng = PulseEngine(
            b.finish(), mesh=jax.make_mesh((P,), ("mem",)), fault_injector=inj
        )
        ft = FaultToleranceConfig(
            store=ArenaStore(tmp), snapshot_every=100, dead_rounds=dead_rounds,
            replication=ReplicationConfig(policy="failover"),
        )
        specs = {
            "list": StructureSpec(
                linked_list.find_iterator(), (head,), group="list"
            ),
        }
        if not reads_only:
            specs["list_ins"] = StructureSpec(
                linked_list.insert_iterator(), (head,), group="list",
                takes_value=True,
            )
        svc = PulseService(
            eng, specs, slots_per_structure=8, quantum=6,
            fault_tolerance=ft,
        )
        reqs = []
        for i in range(36):
            if not reads_only and i % 4 == 2:
                reqs.append(TraversalRequest(
                    i, "list_ins", 1000 + i, value=i * 11,
                    tenant="w", arrive_round=i // 8,
                ))
            else:
                reqs.append(TraversalRequest(
                    i, "list", int(keys[(i * 7) % len(keys)]),
                    tenant="r", arrive_round=i // 8,
                ))
        m = svc.run(reqs)
        rep = svc._replicas
        recovered, _info = ft.store.recover()
        ft.store.close()
        return reqs, m, eng.arena, rep, recovered

    with tempfile.TemporaryDirectory() as d:
        ref_r, ref_m, ref_ar, _, _ = serve(d, None, reads_only=True)
    for dead in range(P):
        plan = FaultPlan(kill_shard=dead, kill_call=4, kill_superstep=2)
        with tempfile.TemporaryDirectory() as d:
            r1, m1, ar1, rep1, rec1 = serve(d, plan, reads_only=True)
        tag = f"svc-kill/read-only/shard={dead}"
        assert m1.recoveries == 1, (tag, m1.recoveries)
        assert m1.failover_quanta >= 1, (tag, m1.failover_quanta)
        assert m1.retries == 0 and m1.retry_exhausted == 0, (tag, m1.retries)
        for a, b_ in zip(ref_r, r1):
            assert a.status == b_.status == STATUS_DONE, (tag, a.req_id)
            assert b_.retries == 0, (tag, b_.req_id)
            np.testing.assert_array_equal(
                a.result, b_.result, err_msg=f"{tag}/{a.req_id}"
            )
        np.testing.assert_array_equal(
            np.asarray(ref_ar.data), np.asarray(ar1.data), err_msg=tag
        )
    print(f"svc kill matrix (read-only) ok: {P} shards, zero STATUS_RETRY, "
          f"results identical")

    with tempfile.TemporaryDirectory() as d:
        w_r, w_m, w_ar, w_rep, w_rec = serve(d, None)
    assert w_m.replica_quanta > 0
    for dead in range(P):
        plan = FaultPlan(kill_shard=dead, kill_call=4, kill_superstep=2)
        with tempfile.TemporaryDirectory() as d:
            r1, m1, ar1, rep1, rec1 = serve(d, plan)
        tag = f"svc-kill/mixed/shard={dead}"
        assert m1.recoveries == 1, (tag, m1.recoveries)
        # (a) held throughout: verify_every_quantum raises on divergence
        assert m1.replica_quanta > 0, tag
        # (b) reads never charged a retry, never retired STATUS_RETRY
        for b_ in r1:
            if b_.tenant == "r":
                assert b_.status == STATUS_DONE and b_.retries == 0, (
                    tag, b_.req_id, b_.status, b_.retries,
                )
        # results + final arena bit-identical to the failure-free run
        assert m1.completed == w_m.completed == 36, (tag, m1.completed)
        for a, b_ in zip(w_r, r1):
            assert a.status == b_.status, (tag, a.req_id)
            np.testing.assert_array_equal(
                a.result, b_.result, err_msg=f"{tag}/{a.req_id}"
            )
        np.testing.assert_array_equal(
            np.asarray(w_ar.data), np.asarray(ar1.data), err_msg=tag
        )
        np.testing.assert_array_equal(
            np.asarray(w_ar.heap), np.asarray(ar1.heap), err_msg=tag
        )
        # (c) primary == replica == durable oracle, post-recovery
        rep1.verify(ar1)
        for field in ("data", "bounds", "perms", "heap"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ar1, field)),
                np.asarray(getattr(rec1, field)),
                err_msg=f"{tag}/oracle.{field}",
            )
    print(f"svc kill matrix (mixed r/w) ok: {P} shards, replica verified "
          f"per quantum, primary == replica == oracle")


if __name__ == "__main__":
    assert jax.device_count() == P, jax.devices()
    check_kill_every_schedule()
    check_kill_superstep_counting()
    check_drop_retransmit_identity()
    check_drop_write_path_validity()
    check_delay_identity()
    check_replica_fanout_matrix()
    check_replication_service_matrix()
    print("ALL FAULT-INJECTION CHECKS PASSED")
