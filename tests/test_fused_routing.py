"""Fused device-resident routing: retrace/donation regressions (multi-device
checks run subprocess-isolated; executable-reuse checks for the kernel and
local engine paths run in-process on the single default device)."""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from repro.core.engine import PulseEngine
from repro.core.iterator import STATUS_DONE
from repro.core.structures import hash_table, linked_list

ROOT = Path(__file__).resolve().parents[1]
RNG = np.random.default_rng(31)


def test_fused_routing_subprocess():
    """Retracing + donation + resident-arena checks need >1 XLA device, so
    they run in a subprocess with their own XLA_FLAGS (same isolation rule as
    test_distributed_routing)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "helpers" / "fused_checks.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL FUSED CHECKS PASSED" in proc.stdout


def test_kernel_wave_executables_reused_across_waves():
    """A second identical wave-scheduled run must be all cache hits: the
    donating pulse_chase executable retraces zero times."""
    from repro.kernels.pulse_chase import ops

    keys = RNG.choice(np.arange(10**5), size=128, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, 128).astype(np.int32)
    ar, heads = hash_table.build(keys, values, 8)
    it = hash_table.find_iterator(8)
    q = np.concatenate([keys[:24], RNG.integers(10**5, 10**6, 8).astype(np.int32)])
    ptr0, scr0 = it.init(jnp.asarray(q), jnp.asarray(heads))
    logic = ops.iterator_logic(it)

    first = ops.pulse_chase_waves(
        ar.data, ptr0, scr0, np.zeros(32, np.int32),
        logic_fn=logic, max_steps=64, depth_quantum=8, wave=8,
    )
    assert first[3].chunks > 1  # the schedule actually spans several waves
    ops.CACHE_STATS.reset()
    second = ops.pulse_chase_waves(
        ar.data, ptr0, scr0, np.zeros(32, np.int32),
        logic_fn=logic, max_steps=64, depth_quantum=8, wave=8,
    )
    assert ops.CACHE_STATS.traces == 0, ops.CACHE_STATS
    np.testing.assert_array_equal(first[0], second[0])
    np.testing.assert_array_equal(first[1], second[1])


def test_pulse_chase_public_wrapper_preserves_caller_buffers():
    """ops.pulse_chase donates internally but copies first: the caller's
    arrays must survive the call and be reusable."""
    from repro.kernels.pulse_chase import ops

    keys = np.arange(32, dtype=np.int32)
    ar, head = linked_list.build(keys, keys * 3)
    it = linked_list.find_iterator()
    ptr0, scr0 = it.init(jnp.asarray(keys[:8]), head)
    st0 = jnp.zeros(8, jnp.int32)
    logic = ops.iterator_logic(it)
    r1 = ops.pulse_chase(ar.data, ptr0, scr0, st0, logic_fn=logic, num_steps=40)
    assert not ptr0.is_deleted() and not scr0.is_deleted() and not st0.is_deleted()
    r2 = ops.pulse_chase(ar.data, ptr0, scr0, st0, logic_fn=logic, num_steps=40)
    np.testing.assert_array_equal(np.asarray(r1[1]), np.asarray(r2[1]))


def test_engine_local_path_caches_and_preserves_inputs():
    """Repeated same-shaped local executes reuse one compiled executable
    (donating copies, so the caller's arrays stay alive)."""
    keys = np.arange(64, dtype=np.int32)
    values = RNG.integers(0, 10**6, 64).astype(np.int32)
    ar, head = linked_list.build(keys, values)
    it = linked_list.find_iterator()
    eng = PulseEngine(ar)
    ptr0, scr0 = it.init(jnp.asarray(keys[:16]), head)
    r1 = eng.execute(it, ptr0, scr0, max_iters=256)
    assert not ptr0.is_deleted() and not scr0.is_deleted()
    r2 = eng.execute(it, ptr0, scr0, max_iters=256)
    assert len(eng._local_jit) == 1
    np.testing.assert_array_equal(r1.scratch, r2.scratch)
    assert (r1.status == STATUS_DONE).all()
