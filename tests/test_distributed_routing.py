"""Distributed routing integration tests.

These need >1 XLA host device, and jax pins the device count at first init,
so they run in a subprocess with its own XLA_FLAGS (in-process tests keep
seeing 1 device, matching the dry-run isolation rule)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_distributed_routing_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the helper sets its own
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "helpers" / "distributed_checks.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
