"""ISA VM tests: verifier rules + hand-assembled programs vs traced oracles."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import isa
from repro.core.iterator import STATUS_DONE, STATUS_FAULT, execute_batched
from repro.core.structures import bst, btree, hash_table, linked_list
from repro.core.structures import isa_programs

pytestmark = pytest.mark.slow  # VM-vs-oracle sweeps; full CI lane only

RNG = np.random.default_rng(7)


def _unique_keys(n, lo=0, hi=10**6):
    return RNG.choice(np.arange(lo, hi, dtype=np.int64), size=n, replace=False).astype(
        np.int32
    )


# ----------------------------- verifier -------------------------------------


def test_verifier_rejects_backward_jump():
    a = isa.Asm(scratch_words=1, node_words=4)
    a.label("top")
    a.movi(0, 1)
    a.ret()
    with pytest.raises(ValueError, match="forward"):
        a.jmp("top")  # label already behind
        a.finish()


def test_verifier_rejects_unterminated_program():
    a = isa.Asm(scratch_words=1, node_words=4)
    a.movi(0, 1)
    with pytest.raises(ValueError, match="NEXT_ITER or RETURN"):
        a.finish()


def test_verifier_rejects_bad_scratch_index():
    a = isa.Asm(scratch_words=2, node_words=4)
    a.loads(0, 5)
    a.ret()
    with pytest.raises(ValueError, match="scratch index"):
        a.finish()


def test_verifier_bounds_node_index():
    a = isa.Asm(scratch_words=2, node_words=4)
    a.loadn(0, 9)
    a.ret()
    with pytest.raises(ValueError, match="node index"):
        a.finish()


def test_op_names_exhaustive_over_real_opcode_set():
    """The dead SELECT stub is gone; OP_NAMES/disasm cover every opcode."""
    assert not hasattr(isa, "SELECT")
    assert set(isa.OP_NAMES) == set(isa.ALL_OPS)
    assert isa.ALL_OPS == tuple(range(len(isa.ALL_OPS)))  # dense encoding
    # disasm of a program touching the store class never prints '?'
    a = isa.Asm(scratch_words=2, node_words=4)
    a.movi(0, 1)
    a.storen(1, 0)
    a.alloc(1)
    a.setptr(2, 0, 0)
    a.free(0)
    a.ret()
    text = a.finish().disasm()
    assert "?" not in text
    for name in ("STOREN", "ALLOC", "SETPTR", "FREE"):
        assert name in text


def test_verifier_bounds_store_class_indices():
    for build in (
        lambda a: a.storen(9, 0),  # node index out of range
        lambda a: a.setptr(9, 0, 0),
        lambda a: a.alloc(7),  # scratch index out of range
    ):
        a = isa.Asm(scratch_words=2, node_words=4)
        build(a)
        a.ret()
        with pytest.raises(ValueError, match="out of range"):
            a.finish()


# ----------------------- programs vs traced oracles -------------------------


def test_isa_list_find_equals_traced():
    keys = _unique_keys(128)
    values = RNG.integers(0, 10**6, 128).astype(np.int32)
    ar, head = linked_list.build(keys, values)
    traced = linked_list.find_iterator()
    prog = isa_programs.list_find_program()
    vm = isa.as_pulse_iterator(prog)
    queries = np.concatenate([keys[:40], _unique_keys(40, hi=10**4)])
    ptr0, scr0 = traced.init(jnp.asarray(queries), head)
    r_t = execute_batched(traced, ar, ptr0, scr0, max_iters=500)
    r_v = execute_batched(vm, ar, ptr0, scr0, max_iters=500)
    np.testing.assert_array_equal(np.asarray(r_t[1]), np.asarray(r_v[1]))  # scratch
    np.testing.assert_array_equal(np.asarray(r_t[2]), np.asarray(r_v[2]))  # status
    np.testing.assert_array_equal(np.asarray(r_t[3]), np.asarray(r_v[3]))  # iters


def test_isa_hash_find_equals_traced():
    n, n_buckets = 300, 32
    keys = _unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, heads = hash_table.build(keys, values, n_buckets)
    traced = hash_table.find_iterator(n_buckets)
    vm = isa.as_pulse_iterator(isa_programs.hash_find_program())
    queries = np.concatenate([keys[:60], _unique_keys(60, hi=10**4)])
    ptr0, scr0 = traced.init(jnp.asarray(queries), jnp.asarray(heads))
    r_t = execute_batched(traced, ar, ptr0, scr0, max_iters=500)
    r_v = execute_batched(vm, ar, ptr0, scr0, max_iters=500)
    np.testing.assert_array_equal(np.asarray(r_t[1]), np.asarray(r_v[1]))
    np.testing.assert_array_equal(np.asarray(r_t[2]), np.asarray(r_v[2]))


def test_isa_bst_find_equals_traced():
    n = 800
    keys = _unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, root, _ = bst.build(keys, values)
    traced = bst.find_iterator()
    vm = isa.as_pulse_iterator(isa_programs.bst_find_program())
    queries = np.concatenate([keys[:60], _unique_keys(60, hi=10**4)])
    ptr0, scr0 = traced.init(jnp.asarray(queries), root)
    r_t = execute_batched(traced, ar, ptr0, scr0, max_iters=200)
    r_v = execute_batched(vm, ar, ptr0, scr0, max_iters=200)
    np.testing.assert_array_equal(np.asarray(r_t[1]), np.asarray(r_v[1]))
    np.testing.assert_array_equal(np.asarray(r_t[2]), np.asarray(r_v[2]))


def test_isa_btree_find_equals_traced():
    n = 1200
    keys = _unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, root, _ = btree.build(keys, values)
    traced = btree.find_iterator()
    vm = isa.as_pulse_iterator(isa_programs.btree_find_program())
    queries = np.concatenate([keys[:60], _unique_keys(60, hi=10**4)])
    ptr0, scr0 = traced.init(jnp.asarray(queries), root)
    r_t = execute_batched(traced, ar, ptr0, scr0, max_iters=100)
    r_v = execute_batched(vm, ar, ptr0, scr0, max_iters=100)
    np.testing.assert_array_equal(np.asarray(r_t[1]), np.asarray(r_v[1]))
    np.testing.assert_array_equal(np.asarray(r_t[2]), np.asarray(r_v[2]))
    np.testing.assert_array_equal(np.asarray(r_t[3]), np.asarray(r_v[3]))


# --------------------------- dispatch model ---------------------------------


def test_dispatch_offloads_memory_bound_only():
    from repro.core import dispatch

    lst = linked_list.find_iterator()
    d = dispatch.offload_decision(lst, linked_list.NODE_WORDS)
    assert d.offload, d.reason  # t_c/t_d ~ 0.06 in the paper (hash/list)

    # a compute-heavy iterator must be rejected (run at CPU node)
    def heavy_next(node, ptr, scratch):
        x = scratch
        for _ in range(200):
            x = x * 3 + 1
        return node[2], x

    def heavy_end(node, ptr, scratch):
        return node[2] == -1, scratch

    from repro.core.iterator import PulseIterator

    heavy = PulseIterator(3, heavy_next, heavy_end, name="heavy")
    d2 = dispatch.offload_decision(heavy, linked_list.NODE_WORDS)
    assert not d2.offload, d2.reason


def test_schedule_decision_overlap_model():
    from repro.core import dispatch

    lst = linked_list.find_iterator()
    # single node: nothing to overlap
    d = dispatch.schedule_decision(lst, linked_list.NODE_WORDS, 1)
    assert d.schedule == "local"
    # multi-shard: neither the chase nor the fabric phase dominates, so the
    # wavefront-pipelined schedule hides min(t_local, t_fabric)
    d = dispatch.schedule_decision(lst, linked_list.NODE_WORDS, 8)
    assert d.schedule == "pipelined", d.reason
    assert 0.0 < d.overlap_frac <= 0.5
    assert d.t_local_ns > 0 and d.t_fabric_ns > 0
    # when one phase fully dominates, serialized fused wins (no overlap to
    # harvest): force it via the min_overlap knob
    d = dispatch.schedule_decision(
        lst, linked_list.NODE_WORDS, 8, min_overlap=0.99
    )
    assert d.schedule == "fused"


def test_dispatch_isa_count_is_longest_path():
    from repro.core import dispatch, isa as isa_mod

    prog = isa_programs.list_find_program()
    vm = isa_mod.as_pulse_iterator(prog)
    n = dispatch.count_instructions(vm, prog.node_words)
    assert n == dispatch.isa_longest_path(prog)
    assert 0 < n <= len(prog)  # a DAG path can never exceed program length
