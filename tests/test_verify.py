"""pulse-verify: the eBPF-style static verifier for PULSE ISA programs.

Covers the admission pass itself (mutant corpus with expected diagnostic
codes, certificate facts), build-time assembler/Program validation, the
serving layer's reject-before-enqueue, the CLI + golden disasm files, a
random-program property test (accepted => runs to RET/budget without
faults on a compatible arena), and the 8-shard read-only specialization
bit-identity gate (subprocess, like the other distributed suites).
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import isa
from repro.core.isa import (
    FREE,
    GETPTR,
    JMP,
    JNE,
    LOADN,
    LOADS,
    MOVE,
    MOVI,
    NEXT_ITER,
    RETURN,
    SETPTR,
    STOREN,
    STORES,
    Asm,
    Program,
)
from repro.core.structures import isa_programs
from repro.core.verify import (
    E_BAD_OPCODE,
    E_DOUBLE_STAGE,
    E_FALLTHROUGH,
    E_HALT,
    E_JUMP_RANGE,
    E_LOOP,
    E_NODE_RANGE,
    E_PROVENANCE,
    E_REG_RANGE,
    E_SCRATCH_RANGE,
    E_UNDEF_READ,
    E_UNREACHABLE,
    ProgramFacts,
    VerifyError,
    analyze_program,
    annotate_disasm,
    verify_program,
)

ROOT = Path(__file__).resolve().parents[1]
GOLDEN = ROOT / "tests" / "golden" / "pulse_verify"


def _mutate(prog: Program, row: int, values, name="mutant") -> Program:
    code = prog.code.copy()
    code[row] = values
    return Program(code, prog.scratch_words, prog.node_words, name=name)


def _codes(prog: Program):
    _, diags = analyze_program(prog)
    return {d.code for d in diags}, diags


# ------------------------- shipped programs verify clean ---------------------


def test_all_shipped_programs_verify_clean():
    for name, prog in isa_programs.all_programs().items():
        facts = verify_program(prog)  # raises on rejection
        assert isinstance(facts, ProgramFacts), name
        assert facts.scratch_words_used <= prog.scratch_words


def test_facts_read_write_split():
    ro = verify_program(isa_programs.list_find_program())
    rw = verify_program(isa_programs.bst_update_program())
    assert ro.read_only and not ro.mutates
    assert rw.mutates and not rw.read_only
    from repro.core.arena import PERM_READ, PERM_WRITE

    assert ro.perm_mask == PERM_READ
    assert rw.perm_mask == (PERM_READ | PERM_WRITE)


def test_facts_max_path_matches_dispatch_model():
    from repro.core.dispatch import isa_longest_path

    for prog in isa_programs.all_programs().values():
        assert verify_program(prog).max_path_len == isa_longest_path(prog)


# ------------------------------ mutant corpus --------------------------------
# ~10 corrupted shipped programs; every one must be rejected with the
# expected diagnostic code pointing at the corrupted instruction.

LIST = isa_programs.list_find_program
UPD = isa_programs.bst_update_program

MUTANTS = [
    # (name, program-builder, expected code, expected pc)
    ("bad_opcode", lambda: _mutate(LIST(), 3, [99, 0, 0, 0]), E_BAD_OPCODE, 3),
    (
        "jump_past_end",
        lambda: _mutate(LIST(), 5, [JNE, 0, 1, 99]),
        E_JUMP_RANGE,
        5,
    ),
    (
        "register_out_of_range",
        lambda: _mutate(LIST(), 0, [LOADS, 20, 0, 0]),
        E_REG_RANGE,
        0,
    ),
    (
        "node_index_out_of_range",
        lambda: _mutate(LIST(), 1, [LOADN, 1, 0, 7]),
        E_NODE_RANGE,
        1,
    ),
    (
        "scratch_index_out_of_range",
        lambda: _mutate(LIST(), 6, [STORES, 2, 0, 9]),
        E_SCRATCH_RANGE,
        6,
    ),
    (
        "falls_off_end",
        lambda: Program(LIST().code[:16], 3, 4, name="truncated"),
        E_FALLTHROUGH,
        14,
    ),
    ("halt_reachable", lambda: _mutate(LIST(), 9, [0, 0, 0, 0]), E_HALT, 9),
    (
        "backward_jump_loop",
        lambda: _mutate(LIST(), 14, [JNE, 3, 4, 5]),
        E_LOOP,
        None,  # the whole cycle is implicated, not one pc
    ),
    (
        "unreachable_code",
        lambda: _mutate(LIST(), 5, [JMP, 0, 0, 10]),
        E_UNREACHABLE,
        6,
    ),
    (
        "use_before_def",
        lambda: _mutate(LIST(), 0, [MOVE, 0, 7, 0]),
        E_UNDEF_READ,
        0,
    ),
    (
        "dead_store_after_terminal",
        lambda: Program(
            np.vstack([LIST().code, [[STOREN, 2, 0, 1]]]), 3, 4, name="dead"
        ),
        E_UNREACHABLE,
        17,
    ),
    (
        "free_while_store_staged",
        lambda: _mutate(UPD(), 13, [FREE, 9, 0, 0]),
        E_DOUBLE_STAGE,
        13,
    ),
    (
        "setptr_without_provenance",
        lambda: _mutate(UPD(), 12, [SETPTR, 7, 0, 1]),
        E_PROVENANCE,
        12,
    ),
]


@pytest.mark.parametrize("name,build,code,pc", MUTANTS, ids=[m[0] for m in MUTANTS])
def test_mutant_rejected_with_expected_code(name, build, code, pc):
    prog = build()
    with pytest.raises(VerifyError) as ei:
        verify_program(prog)
    err = ei.value
    assert code in err.codes, (name, err.codes)
    if pc is not None:
        assert any(d.pc == pc for d in err.diagnostics if d.code == code), (
            name,
            [(d.code, d.pc) for d in err.diagnostics],
        )
    # diagnostics render instruction-pointed messages
    assert any(f"pc {d.pc}" in str(err) or f"pc={d.pc}" in str(err)
               or str(d.pc) in str(err) for d in err.diagnostics)


def test_mutant_corpus_is_fully_rejected():
    """The acceptance gate: 100% of the corpus rejected."""
    rejected = 0
    for _, build, _, _ in MUTANTS:
        _, diags = analyze_program(build())
        rejected += bool(diags)
    assert rejected == len(MUTANTS)


def test_verify_error_is_structured():
    with pytest.raises(VerifyError) as ei:
        verify_program(_mutate(LIST(), 3, [99, 0, 0, 0], name="structured"))
    e = ei.value
    assert e.name == "structured"
    assert isinstance(e.codes, tuple) and E_BAD_OPCODE in e.codes
    assert isinstance(e, ValueError)  # registration sites catching ValueError


# --------------------- build-time validation (Asm / Program) -----------------


def test_asm_rejects_duplicate_label():
    a = Asm(scratch_words=1, node_words=2)
    a.label("top")
    a.movi(0, 1)
    with pytest.raises(ValueError, match="duplicate label"):
        a.label("top")


def test_asm_rejects_alu_register_out_of_range():
    a = Asm(scratch_words=1, node_words=2)
    a.movi(0, 1)
    a.movi(1, 2)
    a.add(2, 0, 20)  # rs2 rides the imm field; 20 >= NUM_REGS
    a.ret()
    with pytest.raises(ValueError, match="register 20 out of range"):
        a.finish()


@pytest.mark.parametrize(
    "kwargs,match",
    [
        (dict(code=np.zeros((0, 4), np.int32)), "empty program"),
        (dict(code=np.zeros((3, 3), np.int32)), r"\(T, 4\)"),
        (dict(code=np.zeros((3, 4), np.float32)), "integer"),
        (dict(code=np.zeros((3, 4), np.int32), scratch_words=-1), "scratch_words"),
        (dict(code=np.zeros((3, 4), np.int32), node_words=0), "node_words"),
    ],
)
def test_program_structural_validation(kwargs, match):
    base = dict(code=None, scratch_words=2, node_words=4)
    base.update(kwargs)
    with pytest.raises(ValueError, match=match):
        Program(base["code"], base["scratch_words"], base["node_words"])


def test_asm_jump_past_end_fails_at_finish():
    a = Asm(scratch_words=1, node_words=2)
    a.movi(0, 0)
    a.jmp("nowhere")
    a.ret()
    with pytest.raises(ValueError, match="undefined label"):
        a.finish()  # unresolved label: fails at build, not mid-traversal


# ---------------------- as_pulse_iterator admission --------------------------


@pytest.mark.slow
def test_as_pulse_iterator_verifies_by_default():
    with pytest.raises(VerifyError):
        isa.as_pulse_iterator(_mutate(LIST(), 3, [99, 0, 0, 0]))
    vm = isa.as_pulse_iterator(isa_programs.list_find_program())
    assert vm.facts is not None and vm.facts.read_only
    unchecked = isa.as_pulse_iterator(
        isa_programs.list_find_program(), verify=False
    )
    assert unchecked.facts is None  # conservative fallback path


@pytest.mark.slow
def test_dead_store_demotion_to_read_only_path():
    """Satellite: Program.mutates over-approximates; facts.mutates decides.

    The dead-store variant is rejected outright by the verifier (unreachable
    code).  Unverified, the conservative opcode scan routes it down the
    mutating path; the verified original supplies step_fn (read path).
    """
    dead = Program(
        np.vstack([LIST().code, [[STOREN, 2, 0, 1]]]), 3, 4, name="dead"
    )
    assert dead.mutates  # whole-array opcode scan
    vm_rw = isa.as_pulse_iterator(dead, verify=False)
    assert vm_rw.mutates and vm_rw.mut_fn is not None
    vm_ro = isa.as_pulse_iterator(isa_programs.list_find_program())
    assert not vm_ro.mutates and vm_ro.step_fn is not None


# ----------------------- serving: reject-before-enqueue ----------------------


@pytest.mark.slow
def test_service_rejects_unverified_unsafe_program_at_registration():
    import jax.numpy as jnp

    from repro.core.engine import PulseEngine
    from repro.core.structures import linked_list
    from repro.serving.traversal_service import PulseService, StructureSpec

    keys = np.arange(32, dtype=np.int32)
    values = np.arange(32, dtype=np.int32)
    ar, head = linked_list.build(keys, values)
    engine = PulseEngine(ar)
    bad = _mutate(LIST(), 14, [JNE, 3, 4, 5], name="looping_find")
    spec = StructureSpec(
        iterator=isa.as_pulse_iterator(bad, verify=False),  # sneaks past build
        init_args=(head,),
    )
    with pytest.raises(VerifyError, match="looping_find") as ei:
        PulseService(engine, {"lst": spec})
    assert "lst" in str(ei.value)  # names the structure being registered
    assert E_LOOP in ei.value.codes

    # a certified spec (facts already attached) registers without re-analysis
    ok = StructureSpec(
        iterator=isa.as_pulse_iterator(isa_programs.list_find_program()),
        init_args=(head,),
    )
    svc = PulseService(engine, {"lst": ok})
    assert "lst" in svc.groups
    # hand-written JAX iterators have no Program to analyze: accepted as-is
    svc2 = PulseService(
        engine,
        {"lst": StructureSpec(iterator=linked_list.find_iterator(),
                              init_args=(head,))},
    )
    assert "lst" in svc2.groups


# ------------------------------- CLI + goldens -------------------------------


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "pulse_verify.py"), *args],
        env=env, capture_output=True, text=True, timeout=300,
    )


def test_cli_verifies_all_shipped_programs():
    proc = _run_cli("--all")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for name in isa_programs.all_programs():
        assert f"OK     {name}" in proc.stdout
    assert "REJECT" not in proc.stdout


def test_cli_golden_disasm_files_are_current():
    proc = _run_cli("--all", "--golden", str(GOLDEN))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "DRIFT" not in proc.stdout


def test_cli_unknown_program_is_a_usage_error():
    proc = _run_cli("no_such_program")
    assert proc.returncode == 2


def test_golden_files_match_annotate_disasm():
    for name, prog in isa_programs.all_programs().items():
        golden = (GOLDEN / f"{name}.disasm").read_text()
        assert golden == annotate_disasm(prog)
        assert "verdict: OK" in golden


# --------------------- property test: accepted => runs clean -----------------


def _compatible_arena(capacity=48, node_words=4):
    """Every node word is a valid in-range pointer and every shard grants
    READ|WRITE: the only ways a traversal can fault are verifier-caught."""
    import jax.numpy as jnp

    from repro.core.arena import HEAP_WORDS, PERM_READ, PERM_WRITE, Arena

    data = (
        (np.arange(capacity)[:, None] * 7 + np.arange(node_words)[None, :] * 3)
        % capacity
    ).astype(np.int32)
    return Arena(
        data=jnp.asarray(data),
        bounds=jnp.asarray([0, capacity], jnp.int32),
        perms=jnp.asarray([PERM_READ | PERM_WRITE], jnp.int32),
        heap=jnp.zeros((1, HEAP_WORDS), jnp.int32),
    )


def _random_program(rng: np.random.Generator) -> Program:
    """Biased random generator: mostly-plausible read-only programs.

    Store-class ops are excluded on purpose -- arbitrary masked stores would
    corrupt the arena's every-word-is-a-pointer invariant, making runtime
    translation faults a *data* property rather than something the verifier
    could ever prove.  The write path's staging discipline is covered by the
    mutant corpus above.
    """
    S, W = 3, 4
    n_body = int(rng.integers(3, 10))
    rows = []
    defined = []
    ptr_regs = []  # defined by LOADN/GETPTR: provenance-safe NEXT_ITER args

    def reg(defined_bias=0.85):
        if defined and rng.random() < defined_bias:
            return int(rng.choice(defined))
        return int(rng.integers(0, 18))  # sometimes invalid / undefined

    for _ in range(n_body):
        k = rng.random()
        rd = int(rng.integers(0, 8))
        if k < 0.2:
            rows.append([MOVI, rd, 0, int(rng.integers(-4, 4))])
        elif k < 0.4:
            rows.append([LOADN, rd, 0, int(rng.integers(0, W + 1))])
            ptr_regs.append(rd)
        elif k < 0.5:
            rows.append([LOADS, rd, 0, int(rng.integers(0, S + 1))])
        elif k < 0.6:
            rows.append([STORES, reg(), 0, int(rng.integers(0, S + 1))])
            continue  # no def
        elif k < 0.7:
            rows.append([GETPTR, rd, 0, 0])
            ptr_regs.append(rd)
        elif k < 0.85:
            op = int(rng.choice([isa.ADD, isa.SUB, isa.AND, isa.OR]))
            rows.append([op, rd, reg(), reg()])
        else:
            # forward conditional jump: sometimes to the terminal, sometimes
            # past the end of the program (the verifier's problem, not ours)
            tgt = int(rng.integers(len(rows) + 1, n_body + 3))
            rows.append([JNE, reg(), reg(), tgt])
            continue
        defined.append(rd)
    # single reachable terminal at pc == n_body (jumps may legally target it)
    if ptr_regs and rng.random() < 0.7:
        rows.append([NEXT_ITER, int(rng.choice(ptr_regs)), 0, 0])
    else:
        rows.append([RETURN, 0, 0, 0])
    return Program(
        np.asarray(rows, np.int32), S, W, name=f"fuzz_{rng.integers(1 << 30)}"
    )


def _fuzz_accepted_programs_run_clean(rng, want_accepted, max_tries):
    from repro.core.iterator import STATUS_FAULT, execute_batched

    ar = _compatible_arena()
    accepted = tries = 0
    while accepted < want_accepted and tries < max_tries:
        tries += 1
        prog = _random_program(rng)
        facts, diags = analyze_program(prog)
        if diags:
            continue
        accepted += 1
        assert facts is not None and not facts.mutates  # store-class excluded
        vm = isa.as_pulse_iterator(prog)
        ptr0 = np.asarray([0, 5, 11, 23], np.int32)
        scr0 = np.zeros((4, prog.scratch_words), np.int32)
        ptr, scr, status, iters = execute_batched(
            vm, ar, ptr0, scr0, max_iters=6
        )
        status = np.asarray(status)
        assert not (status == STATUS_FAULT).any(), (
            prog.name, annotate_disasm(prog), status,
        )
        assert (np.asarray(iters) <= 6).all()
    assert accepted >= min(want_accepted, 3), (
        f"generator too strict: {accepted} accepted in {tries} tries"
    )


@pytest.mark.slow
def test_fuzz_accepted_programs_run_to_ret_or_budget():
    _fuzz_accepted_programs_run_clean(
        np.random.default_rng(7), want_accepted=10, max_tries=600
    )


@pytest.mark.slow
def test_hypothesis_accepted_programs_run_clean():
    hyp = pytest.importorskip(
        "hypothesis",
        reason="hypothesis not installed (pip install -r requirements-dev.txt)",
    )
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def run(seed):
        _fuzz_accepted_programs_run_clean(
            np.random.default_rng(seed), want_accepted=2, max_tries=120
        )

    run()


def test_fuzz_generator_rejections_are_diagnosed():
    """Rejected random programs always carry instruction-pointed findings."""
    rng = np.random.default_rng(11)
    rejected = 0
    for _ in range(200):
        prog = _random_program(rng)
        _, diags = analyze_program(prog)
        if diags:
            rejected += 1
            for d in diags:
                assert d.code and 0 <= d.pc < len(prog) or d.pc == -1
    assert rejected > 0


# --------------------- 8-shard specialization bit-identity -------------------


@pytest.mark.slow
def test_verify_specialization_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the helper sets its own
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "helpers" / "verify_checks.py")],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL VERIFY SPECIALIZATION CHECKS PASSED" in proc.stdout
