"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle, sweeping
shapes and dtypes (the repo-wide kernel contract)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

RNG = np.random.default_rng(42)


def _randn(shape, dtype, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale).astype(dtype)


# ----------------------------- pulse_chase ----------------------------------


@pytest.mark.parametrize("wave", [4, 8])
@pytest.mark.parametrize("n_keys,n_queries", [(128, 16), (512, 32)])
def test_pulse_chase_btree_matches_ref(wave, n_keys, n_queries):
    from repro.core.structures import btree
    from repro.kernels.pulse_chase import ops

    keys = RNG.choice(np.arange(10**5), size=n_keys, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, n_keys).astype(np.int32)
    ar, root, height = btree.build(keys, values)
    it = btree.find_iterator()
    q = np.concatenate(
        [keys[: n_queries // 2],
         RNG.integers(10**5, 10**6, n_queries // 2).astype(np.int32)]
    )
    ptr0, scr0 = it.init(jnp.asarray(q), root)
    status0 = jnp.zeros(n_queries, jnp.int32)
    logic = ops.iterator_logic(it)
    r_ref = ops.pulse_chase(
        ar.data, ptr0, scr0, status0, logic_fn=logic, num_steps=height,
        use_pallas=False,
    )
    r_pal = ops.pulse_chase(
        ar.data, ptr0, scr0, status0, logic_fn=logic, num_steps=height,
        wave=wave, use_pallas=True, interpret=True,
    )
    for a, b, nm in zip(r_ref, r_pal, ["ptr", "scratch", "status", "iters"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=nm)
    assert (np.asarray(r_pal[2]) == 1).all()  # all done within height steps
    found = np.asarray(r_pal[1])[:, 2]
    assert found[: n_queries // 2].all() and not found[n_queries // 2 :].any()


def test_pulse_chase_hash_chain(
):
    from repro.core.structures import hash_table
    from repro.kernels.pulse_chase import ops

    keys = RNG.choice(np.arange(10**5), size=256, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, 256).astype(np.int32)
    ar, heads = hash_table.build(keys, values, 32)
    it = hash_table.find_iterator(32)
    ptr0, scr0 = it.init(jnp.asarray(keys[:32]), jnp.asarray(heads))
    status0 = jnp.zeros(32, jnp.int32)
    logic = ops.iterator_logic(it)
    r_ref = ops.pulse_chase(ar.data, ptr0, scr0, status0, logic_fn=logic,
                            num_steps=32, use_pallas=False)
    r_pal = ops.pulse_chase(ar.data, ptr0, scr0, status0, logic_fn=logic,
                            num_steps=32, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(r_ref[1]), np.asarray(r_pal[1]))
    assert np.asarray(r_pal[1])[:, 2].all()


def test_pulse_chase_wave_iters_exact_vs_xla():
    """The wave-scheduled kernel path must report EXACT per-lane iteration
    counts (not chunk-granular upper bounds): engine backend="kernel" and
    the XLA executor agree bit-for-bit on iters for done and NULL-terminated
    lanes, so downstream hop accounting stops over-counting."""
    from repro.core.engine import PulseEngine
    from repro.core.iterator import STATUS_DONE
    from repro.core.structures import hash_table, linked_list

    keys = RNG.choice(np.arange(10**5), size=256, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, 256).astype(np.int32)
    ar, heads = hash_table.build(keys, values, 8)
    it = hash_table.find_iterator(8)
    q = np.concatenate(
        [keys[:24], RNG.integers(10**5, 10**6, 8).astype(np.int32)]
    )
    ptr0, scr0 = it.init(jnp.asarray(q), jnp.asarray(heads))
    eng = PulseEngine(ar)
    rx = eng.execute(it, ptr0, scr0, max_iters=256, backend="xla")
    rk = eng.execute(it, ptr0, scr0, max_iters=256, backend="kernel")
    np.testing.assert_array_equal(np.asarray(rk.scratch), np.asarray(rx.scratch))
    np.testing.assert_array_equal(np.asarray(rk.status), np.asarray(rx.status))
    np.testing.assert_array_equal(
        np.asarray(rk.iters), np.asarray(rx.iters), err_msg="exact per-lane iters"
    )
    # skewed depths actually exercise multiple retirement waves
    assert rk.stats.chunks > 1 and np.unique(np.asarray(rk.iters)).size > 2

    keys = np.arange(64, dtype=np.int32)
    ar, head = linked_list.build(keys, keys * 7)
    it = linked_list.find_iterator()
    ptr0, scr0 = it.init(jnp.asarray(keys[::4]), head)
    eng = PulseEngine(ar)
    rx = eng.execute(it, ptr0, scr0, max_iters=4096, backend="xla")
    rk = eng.execute(it, ptr0, scr0, max_iters=4096, backend="kernel")
    assert (np.asarray(rx.status) == STATUS_DONE).all()
    np.testing.assert_array_equal(np.asarray(rk.iters), np.asarray(rx.iters))


# --------------------------- flash_attention --------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hk,Lq,Lk,D,causal",
    [
        (2, 4, 2, 128, 128, 64, True),
        (1, 4, 4, 256, 256, 32, True),
        (2, 2, 1, 128, 256, 64, True),  # decode-style Lq < Lk
        (1, 4, 2, 128, 128, 64, False),  # bidirectional (encoder)
    ],
)
@pytest.mark.slow
def test_flash_attention_matches_ref(B, H, Hk, Lq, Lk, D, causal, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import mha_reference

    q = _randn((B, H, Lq, D), dtype)
    k = _randn((B, Hk, Lk, D), dtype)
    v = _randn((B, Hk, Lk, D), dtype)
    o_ref = mha_reference(q, k, v, causal=causal)
    o_pal = flash_attention(q, k, v, causal, 64, 64, True, True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o_ref, np.float32), np.asarray(o_pal, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.slow
def test_flash_attention_grad_matches_ref():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import mha_reference

    q = _randn((1, 2, 128, 32), jnp.float32)
    k = _randn((1, 2, 128, 32), jnp.float32)
    v = _randn((1, 2, 128, 32), jnp.float32)
    g1 = jax.grad(lambda q: flash_attention(q, k, v, True, 64, 64, True, True).sum())(q)
    g2 = jax.grad(lambda q: mha_reference(q, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=2e-5, rtol=2e-5)


# --------------------------- paged_attention --------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hk,D,page,P,N",
    [
        (2, 4, 2, 64, 16, 4, 32),
        (1, 8, 8, 32, 8, 8, 64),
        (3, 4, 1, 64, 16, 3, 16),
    ],
)
@pytest.mark.slow
def test_paged_attention_matches_ref(B, H, Hk, D, page, P, N, dtype):
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_reference

    q = _randn((B, H, D), dtype)
    kp = _randn((N, page, Hk, D), dtype)
    vp = _randn((N, page, Hk, D), dtype)
    pt = jnp.asarray(RNG.integers(0, N, (B, P)), jnp.int32)
    lengths = jnp.asarray(RNG.integers(1, P * page + 1, (B,)), jnp.int32)
    o_ref = paged_attention_reference(q, kp, vp, pt, lengths)
    o_pal = paged_attention(q, kp, vp, pt, lengths, interpret=True, use_pallas=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o_ref, np.float32), np.asarray(o_pal, np.float32),
        atol=tol, rtol=tol,
    )


# ------------------------------ ssd_scan ------------------------------------


@pytest.mark.parametrize("chunk", [32, 64])
@pytest.mark.parametrize("Bt,L,H,dh,N", [(2, 256, 3, 32, 16), (1, 128, 2, 64, 64)])
@pytest.mark.slow
def test_ssd_kernel_matches_chunked_ref(Bt, L, H, dh, N, chunk):
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_chunked_batched

    x = _randn((Bt, L, H, dh), jnp.float32, 0.5)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (Bt, L, H)), jnp.float32)
    A = jnp.asarray(RNG.uniform(-1.0, -0.1, (H,)), jnp.float32)
    B = _randn((Bt, L, N), jnp.float32, 0.5)
    C = _randn((Bt, L, N), jnp.float32, 0.5)
    yr, Sr = ssd_chunked_batched(x, dt, A, B, C, chunk=chunk)
    yk, Sk = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yk), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(Sr), np.asarray(Sk), atol=1e-4, rtol=1e-4)


@pytest.mark.slow
def test_ssd_chunked_equals_sequential_recurrence():
    from repro.kernels.ssd_scan.ref import ssd_chunked, ssd_sequential

    L, dh, N = 256, 32, 16
    x = _randn((L, dh), jnp.float32, 0.5)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (L,)), jnp.float32)
    A = jnp.float32(-0.7)
    B = _randn((L, N), jnp.float32, 0.5)
    C = _randn((L, N), jnp.float32, 0.5)
    y1, S1 = ssd_sequential(x, dt, A, B, C)
    y2, S2 = ssd_chunked(x, dt, A, B, C, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S2), atol=1e-4, rtol=1e-4)
