"""Fault-tolerant arenas: snapshot/restore, commit-log replay recovery, and
degraded-mode serving.

Fast in-process tests cover the ArenaStore durability protocol (atomic
snapshots, torn/corrupt log handling, crash-mid-save) and single-node
service failover (kill -> snapshot restore + log replay + retried quanta,
bit-identical to the failure-free run).  The 8-shard fault-injection matrix
(kill/drop/delay on every schedule x fabric) runs in a subprocess with its
own device count (tests/helpers/ft_checks.py), like the other distributed
suites.
"""

import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import commit
from repro.core.arena import H_EPOCH, ArenaBuilder
from repro.core.engine import PulseEngine
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.iterator import STATUS_DONE, STATUS_RETRY
from repro.core.structures import linked_list
from repro.distributed.arena_ft import (
    ArenaStore,
    CommitLog,
    FaultToleranceConfig,
    RecoveryError,
)
from repro.serving.admission import TraversalRequest
from repro.serving.traversal_service import PulseService, StructureSpec

ROOT = Path(__file__).resolve().parents[1]
P = 4
KEYS = np.arange(100, 124, dtype=np.int32)


def _build():
    b = ArenaBuilder(256, 4, num_shards=P, policy="interleaved")
    head = linked_list.build_into(b, KEYS, KEYS * 2)
    return b.finish(), head


# ----------------------------- snapshot layer --------------------------------


def test_snapshot_roundtrip(tmp_path):
    arena, head = _build()
    store = ArenaStore(tmp_path)
    assert store.snapshot(arena, log_seq=0) == 0
    snap = store.load_snapshot()
    assert snap.log_seq == 0
    assert snap.epoch == int(np.asarray(arena.heap)[:, H_EPOCH].sum())
    for f in ("data", "bounds", "perms", "heap"):
        np.testing.assert_array_equal(
            np.asarray(getattr(snap.arena, f)), np.asarray(getattr(arena, f)), f
        )
    # a later snapshot of mutated state becomes the restore target
    it = linked_list.insert_iterator()
    newk = np.arange(4, dtype=np.int32) + 900
    p0, s0 = it.init(jnp.asarray(newk), jnp.asarray(newk * 2), head)
    _, _, ar2 = commit.sequential_commit_execute(it, arena, p0, s0, max_iters=4096)
    store.snapshot(ar2, log_seq=5)
    snap2 = store.load_snapshot()
    assert snap2.log_seq == 5
    np.testing.assert_array_equal(np.asarray(snap2.arena.data), np.asarray(ar2.data))
    np.testing.assert_array_equal(np.asarray(snap2.arena.heap), np.asarray(ar2.heap))
    # the older snapshot stays addressable until GC'd
    assert store.load_snapshot(step=0).log_seq == 0
    store.close()


def test_log_replay_recovery_bit_identical(tmp_path):
    """Baseline snapshot + logged write quanta replay to the exact arena."""
    arena, head = _build()
    it = linked_list.insert_iterator()
    store = ArenaStore(tmp_path)
    store.register_iterator("ins", it)
    store.ensure_baseline(arena)
    cur, total_commits = arena, 0
    for q in range(3):
        newk = np.arange(4, dtype=np.int32) + 800 + 10 * q
        p0, s0 = it.init(jnp.asarray(newk), jnp.asarray(newk + 1), head)
        _, st, cur = commit.sequential_commit_execute(
            it, cur, p0, s0, max_iters=4096
        )
        store.log_quantum(
            "ins", p0, s0, max_iters=4096, k_local=4, compact=True,
            commits=st.commits, epochs=st.epochs,
        )
        total_commits += st.commits
    recovered, info = store.recover()
    assert info.replayed_quanta == 3
    assert info.replayed_commits == total_commits > 0
    assert info.snapshot_seq == 0  # replay started from the baseline
    np.testing.assert_array_equal(np.asarray(recovered.data), np.asarray(cur.data))
    np.testing.assert_array_equal(np.asarray(recovered.heap), np.asarray(cur.heap))
    store.close()


def test_crash_mid_save_leaves_prior_snapshot_live(tmp_path):
    """A partial snapshot dir (no manifest, LATEST unflipped) is invisible:
    restore + recovery keep using the last complete snapshot."""
    arena, head = _build()
    it = linked_list.insert_iterator()
    store = ArenaStore(tmp_path)
    store.register_iterator("ins", it)
    store.ensure_baseline(arena)
    newk = np.arange(4, dtype=np.int32) + 700
    p0, s0 = it.init(jnp.asarray(newk), jnp.asarray(newk + 1), head)
    _, st, cur = commit.sequential_commit_execute(it, arena, p0, s0, max_iters=4096)
    seq = store.log_quantum(
        "ins", p0, s0, max_iters=4096, k_local=4, compact=True,
        commits=st.commits, epochs=st.epochs,
    )
    # simulate a crash mid-snapshot: data file written, manifest + LATEST not
    partial = tmp_path / f"step_{seq:08d}"
    partial.mkdir()
    np.savez(partial / f"shard_{store.mgr.host_id}.npz", garbage=np.zeros(3))
    assert store.mgr.latest_step() == 0  # pointer never flipped
    snap = store.load_snapshot()
    assert snap.log_seq == 0
    recovered, info = store.recover()
    assert info.replayed_quanta == 1  # the logged quantum replays on top
    np.testing.assert_array_equal(np.asarray(recovered.data), np.asarray(cur.data))
    np.testing.assert_array_equal(np.asarray(recovered.heap), np.asarray(cur.heap))
    store.close()


# ------------------------------ commit log -----------------------------------


def test_commit_log_torn_tail_tolerated(tmp_path):
    path = tmp_path / "log.jsonl"
    log = CommitLog(path)
    assert log.append({"a": 1}) == 1
    assert log.append({"a": 2}) == 2
    log.close()
    with open(path, "a", encoding="utf-8") as f:
        f.write('{"seq": 3, "a":')  # crash mid-append: no newline, torn JSON
    log2 = CommitLog(path)
    assert [e["seq"] for e in log2.entries()] == [1, 2]
    assert log2.seq == 2  # the torn record was never acknowledged
    assert log2.append({"a": 3}) == 3
    log2.close()


def test_commit_log_mid_file_corruption_raises(tmp_path):
    path = tmp_path / "log.jsonl"
    path.write_text('{"seq": 1}\nGARBAGE\n{"seq": 3}\n', encoding="utf-8")
    with pytest.raises(RecoveryError, match="corrupt commit log"):
        CommitLog(path)


def test_recovery_detects_log_replay_divergence(tmp_path):
    """A tampered commit count means snapshot/log are inconsistent: recovery
    must fail loudly rather than hand back a silently-wrong arena."""
    arena, head = _build()
    it = linked_list.insert_iterator()
    store = ArenaStore(tmp_path)
    store.register_iterator("ins", it)
    store.ensure_baseline(arena)
    newk = np.arange(4, dtype=np.int32) + 600
    p0, s0 = it.init(jnp.asarray(newk), jnp.asarray(newk + 1), head)
    _, st, _ = commit.sequential_commit_execute(it, arena, p0, s0, max_iters=4096)
    store.log_quantum(
        "ins", p0, s0, max_iters=4096, k_local=4, compact=True,
        commits=st.commits, epochs=st.epochs,
    )
    store.close()
    log_path = tmp_path / "commit_log.jsonl"
    entries = [json.loads(ln) for ln in log_path.read_text().splitlines()]
    entries[-1]["commits"] += 1
    log_path.write_text("".join(json.dumps(e) + "\n" for e in entries))
    store2 = ArenaStore(tmp_path)
    store2.register_iterator("ins", it)
    with pytest.raises(RecoveryError, match="replay diverged"):
        store2.recover()
    store2.close()


# --------------------------- service failover --------------------------------


def _serve(tmp, plan, *, n_requests=16, retry_budget=5, reads_only=False):
    """Run a mixed read/write workload on a single-node 4-shard engine with
    the full FT stack; returns (requests, metrics, final arena)."""
    arena, head = _build()
    inj = FaultInjector(plan) if plan is not None else None
    eng = PulseEngine(arena, fault_injector=inj)
    # baseline-only snapshots: every acked write quantum sits in the log,
    # so any recovery must actually replay (replayed_commits is meaningful)
    ft = FaultToleranceConfig(
        store=ArenaStore(tmp), snapshot_every=100, retry_budget=retry_budget
    )
    svc = PulseService(
        eng,
        {
            "list": StructureSpec(
                linked_list.find_iterator(), (head,), group="list"
            ),
            "list_ins": StructureSpec(
                linked_list.insert_iterator(), (head,), group="list",
                takes_value=True,
            ),
        },
        slots_per_structure=4,
        quantum=6,
        fault_tolerance=ft,
    )
    reqs = []
    for i in range(n_requests):
        if not reads_only and i % 4 == 2:
            reqs.append(
                TraversalRequest(
                    i, "list_ins", 500 + i, value=i * 3,
                    tenant="w", arrive_round=i // 4,
                )
            )
        else:
            reqs.append(
                TraversalRequest(
                    i, "list", int(KEYS[(i * 5) % len(KEYS)]),
                    tenant="r", arrive_round=i // 4,
                )
            )
    m = svc.run(reqs)
    ft.store.close()
    return reqs, m, eng.arena


def _assert_identical(tag, ref, chaos):
    r0, m0, ar0 = ref
    r1, m1, ar1 = chaos
    assert m0.recoveries == 0 and m0.retries == 0
    assert m1.recoveries == 1, (tag, m1.recoveries)
    assert m1.retries > 0, tag
    assert m1.completed == m0.completed == len(r0), tag
    for a, b in zip(r0, r1):
        assert a.status == b.status, (tag, a.req_id)
        np.testing.assert_array_equal(a.result, b.result, err_msg=f"{tag}/{a.req_id}")
    np.testing.assert_array_equal(np.asarray(ar0.data), np.asarray(ar1.data), tag)
    np.testing.assert_array_equal(np.asarray(ar0.heap), np.asarray(ar1.heap), tag)


def test_service_failover_bit_identical(tmp_path):
    """Kill a shard mid-stream: after snapshot restore + log replay + retried
    in-flight quanta, every request's (status, result) and the final arena
    are bit-identical to the failure-free run."""
    ref = _serve(tmp_path / "ref", None)
    plan = FaultPlan(kill_shard=1, kill_call=8, kill_superstep=1)
    chaos = _serve(tmp_path / "kill", plan)
    _assert_identical("failover", ref, chaos)
    assert chaos[1].replayed_commits > 0  # acked writes really replayed
    assert chaos[1].mean_recovery_ms > 0


def test_service_seeded_kill_sweep(tmp_path):
    """Recovery is kill-point-agnostic: early, mid, and late kills all
    converge to the failure-free answer."""
    ref = _serve(tmp_path / "ref", None)
    for k in (2, 5, 11):
        plan = FaultPlan(kill_shard=k % P, kill_call=k, kill_superstep=1)
        chaos = _serve(tmp_path / f"kill{k}", plan)
        _assert_identical(f"kill@{k}", ref, chaos)


def test_retry_budget_exhaustion_sheds_retry_status(tmp_path):
    """retry_budget=0: occupants of the failed group retire STATUS_RETRY
    (client must resubmit) while later arrivals complete normally."""
    plan = FaultPlan(kill_shard=0, kill_call=1, kill_superstep=1)
    reqs, m, _ = _serve(
        tmp_path, plan, n_requests=8, retry_budget=0, reads_only=True
    )
    assert m.recoveries == 1
    assert m.retry_exhausted > 0
    statuses = {int(r.status) for r in reqs}
    assert STATUS_RETRY in statuses
    assert STATUS_DONE in statuses  # service keeps serving after the kill
    assert statuses <= {STATUS_RETRY, STATUS_DONE}
    # budget-0 retirements are counted as retries too
    assert m.retries >= m.retry_exhausted


# ------------------------- property-based failover ---------------------------


@pytest.mark.slow
def test_random_workload_random_kill_identity():
    """Property: for ANY mixed workload and ANY single-shard kill point, the
    recovered run is bit-identical to the failure-free run."""
    hyp = pytest.importorskip(
        "hypothesis",
        reason="hypothesis not installed (pip install -r requirements-dev.txt)",
    )
    st = hyp.strategies

    @hyp.settings(max_examples=10, deadline=None)
    @hyp.given(
        n_requests=st.integers(min_value=6, max_value=18),
        write_mask=st.integers(min_value=0, max_value=(1 << 18) - 1),
        kill_call=st.integers(min_value=1, max_value=10),
        kill_shard=st.integers(min_value=0, max_value=P - 1),
    )
    def prop(n_requests, write_mask, kill_call, kill_shard):
        def serve(tmp, plan):
            arena, head = _build()
            inj = FaultInjector(plan) if plan is not None else None
            eng = PulseEngine(arena, fault_injector=inj)
            ft = FaultToleranceConfig(store=ArenaStore(tmp), snapshot_every=100)
            svc = PulseService(
                eng,
                {
                    "list": StructureSpec(
                        linked_list.find_iterator(), (head,), group="list"
                    ),
                    "list_ins": StructureSpec(
                        linked_list.insert_iterator(), (head,), group="list",
                        takes_value=True,
                    ),
                },
                slots_per_structure=4,
                quantum=6,
                fault_tolerance=ft,
            )
            reqs = []
            for i in range(n_requests):
                if (write_mask >> i) & 1:
                    reqs.append(TraversalRequest(
                        i, "list_ins", 500 + i, value=i * 3,
                        tenant="w", arrive_round=i // 4,
                    ))
                else:
                    reqs.append(TraversalRequest(
                        i, "list", int(KEYS[(i * 5) % len(KEYS)]),
                        tenant="r", arrive_round=i // 4,
                    ))
            m = svc.run(reqs)
            ft.store.close()
            return reqs, m, eng.arena

        plan = FaultPlan(
            kill_shard=kill_shard, kill_call=kill_call, kill_superstep=1
        )
        with tempfile.TemporaryDirectory() as d0, \
                tempfile.TemporaryDirectory() as d1:
            r0, m0, ar0 = serve(d0, None)
            r1, m1, ar1 = serve(d1, plan)
        # a kill past the run's natural length never fires: nothing to check
        if m1.recoveries == 0:
            assert m1.retries == 0
            return
        assert m1.recoveries == 1
        assert m1.completed == m0.completed == len(r0)
        for a, b in zip(r0, r1):
            assert a.status == b.status, a.req_id
            np.testing.assert_array_equal(a.result, b.result)
        np.testing.assert_array_equal(np.asarray(ar0.data), np.asarray(ar1.data))
        np.testing.assert_array_equal(np.asarray(ar0.heap), np.asarray(ar1.heap))

    prop()


# ------------------------ distributed acceptance matrix ----------------------


@pytest.mark.slow
def test_fault_injection_distributed_subprocess():
    """8-shard kill/drop/delay matrix on every schedule x fabric: clean
    deaths, park-and-retransmit identity, straggler identity."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "helpers" / "ft_checks.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1800,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL FAULT-INJECTION CHECKS PASSED" in proc.stdout
