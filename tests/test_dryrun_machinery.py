"""Dry-run machinery tests that run fast on 1 device:
roofline parsing, shape specs, step builders at reduced scale."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, all_cells, get_reduced_config
from repro.launch import roofline as rl


def test_all_cells_enumeration():
    cells = all_cells()
    # 10 archs x 4 shapes - 1 documented skip (whisper x long_500k)
    assert len(cells) == 39
    assert ("whisper_large_v3", "long_500k") not in cells
    assert ("mamba2_780m", "long_500k") in cells


def test_collective_parser_counts_ring_bytes():
    hlo = """
  %ar = f32[1024,256]{1,0} all-reduce(f32[1024,256] %x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[512,128]{1,0} all-gather(bf16[32,128] %y), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[64]{0} collective-permute(f32[64] %z), source_target_pairs={{0,1}}
  %aa = f32[8,64]{1,0} all-to-all(f32[8,64] %w), replica_groups=[2,8]<=[16]
"""
    out = rl.collective_wire_bytes(hlo)
    ar_bytes = 1024 * 256 * 4
    assert abs(out["all-reduce"] - 2 * ar_bytes * 15 / 16) < 1
    ag_bytes = 512 * 128 * 2
    assert abs(out["all-gather"] - ag_bytes * 3 / 4) < 1
    assert out["collective-permute"] == 64 * 4
    assert abs(out["all-to-all"] - 8 * 64 * 4 * 7 / 8) < 1
    assert out["counts"]["all-reduce"] == 1


def test_collective_parser_ignores_done_ops():
    hlo = """
  %s = f32[128]{0} all-gather-start(f32[32] %x), replica_groups={{0,1,2,3}}
  %d = f32[128]{0} all-gather-done(f32[128] %s)
"""
    out = rl.collective_wire_bytes(hlo)
    assert out["counts"]["all-gather"] == 1


def test_roofline_dominant_term():
    rep = rl.analyze(
        arch="a", shape_name="s", mesh_name="m", chips=256,
        cost={"flops": 1e15, "bytes accessed": 1e9},
        hlo_text="", memory_stats=None, model_flops=6e17,
    )
    assert rep.dominant == "compute"
    assert abs(rep.compute_s - 1e15 / rl.PEAK_FLOPS) < 1e-9


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "mamba2_780m", "granite_moe_1b_a400m"])
def test_step_builders_lower_on_tiny_mesh(arch):
    """build_step lowers (no compile) for each kind on a 1-device mesh with a
    tiny config -- catches spec/struct mismatches without the 512-dev cost."""
    from repro.launch.steps import build_step

    cfg = get_reduced_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for shape_name in ("train_4k", "decode_32k"):
        shape = SHAPES[shape_name]
        small = type(shape)(shape.name, seq_len=64, global_batch=2, kind=shape.kind)
        step, args, in_sh = build_step(cfg, small, mesh)
        with mesh:
            jax.jit(step, in_shardings=in_sh).lower(*args)


def test_model_flops_shapes():
    cfg = get_reduced_config("qwen3_0_6b")
    t = rl.model_flops_for(cfg, SHAPES["train_4k"])
    p = rl.model_flops_for(cfg, SHAPES["prefill_32k"])
    d = rl.model_flops_for(cfg, SHAPES["decode_32k"])
    assert t == 6 * cfg.param_count() * 4096 * 256
    assert p == 2 * cfg.param_count() * 32768 * 32
    assert d == 2 * cfg.param_count() * 128
